"""Loop-aware cost analysis of optimized HLO text.

XLA's HloCostAnalysis (what ``compiled.cost_analysis()`` reports) counts
a while-loop body ONCE — for scan-over-layers models that undercounts
FLOPs/bytes/collectives by ~n_layers, corrupting every roofline term.
This module re-derives the three quantities from ``compiled.as_text()``
with proper multipliers:

  * while ops carry ``backend_config={"known_trip_count":{"n":"N"}}`` —
    body + condition costs are multiplied by N (nested loops compose);
  * FLOPs: dot ops contribute 2 * prod(result_dims) * K, with K taken
    from the lhs operand's shape at ``lhs_contracting_dims`` (resolved
    through the computation's SSA symbol table);
  * HBM bytes: per instruction, result + operand bytes — for fusions
    only the fusion's operands/result count (internal ops never touch
    HBM), which models post-fusion traffic;
  * collective wire bytes: result bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute(+ -start variants),
    with an all-reduce 2x ring factor, loop-multiplied like everything
    else.

The SPMD-partitioned module is per-device, so all outputs are per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_INSTR_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_RTYPE_RE = re.compile(r"\w+\[[\d,]*\](?:\{[^}]*\})?")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALL_RE = re.compile(r"(?:calls|condition|body|to_apply)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _type_bytes(type_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_text: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Instr:
    name: str
    rtype: str
    opcode: str
    operands: List[str]
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]
    instrs: List[Instr]

    def symbol(self, name: str) -> Optional[str]:
        if name in self.params:
            return self.params[name]
        for i in self.instrs:
            if i.name == name:
                return i.rtype
        return None


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line)
            if m and line.rstrip().endswith("{"):
                params = {}
                for p in m.group(2).split(","):
                    p = p.strip()
                    if ":" in p:
                        pname, ptype = p.split(":", 1)
                        params[pname.strip().lstrip("%")] = ptype.strip()
                cur = Computation(m.group(1), params, [])
                if line.lstrip().startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.instrs.append(ins)
    return comps, entry


def _balanced(line: str, start: int) -> int:
    """Index of the ')' closing the '(' at ``start`` (or len(line))."""
    depth = 0
    for j in range(start, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                return j
    return len(line) - 1


def _parse_instr(line: str) -> Optional[Instr]:
    m = _INSTR_HEAD_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    if i >= len(line):
        return None
    if line[i] == "(":  # tuple result type (may contain /*index=N*/)
        j = _balanced(line, i)
        rtype = line[i:j + 1]
        i = j + 1
    else:
        m2 = _RTYPE_RE.match(line, i)
        if not m2:
            return None
        rtype = m2.group(0)
        i = m2.end()
    m3 = _OPCODE_RE.match(line, i)
    if not m3:
        return None
    opcode = m3.group(1)
    start = m3.end() - 1
    end = _balanced(line, start)
    opseg = line[start + 1:end]
    rest = line[end + 1:]
    return Instr(name, rtype, opcode, _OPERAND_RE.findall(opseg), rest)


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    wire: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    def _acc(self, op: str, b: float):
        self.bytes += b
        self.by_op[op] = self.by_op.get(op, 0.0) + b

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for c in _COLLECTIVES:
            self.wire[c] += other.wire[c] * mult
            self.coll_counts[c] += other.coll_counts[c] * mult
        for k, v in other.by_op.items():
            self.by_op[k] = self.by_op.get(k, 0.0) + v * mult


def _dot_flops(comp: Computation, ins: Instr) -> float:
    rdims = _shape_dims(ins.rtype) or []
    out = 1.0
    for d in rdims:
        out *= d
    k = 1.0
    mc = _LHS_CONTRACT_RE.search(ins.rest)
    if mc and ins.operands:
        lhs_t = comp.symbol(ins.operands[0])
        if lhs_t is not None:
            ldims = _shape_dims(lhs_t) or []
            for idx in filter(None, mc.group(1).split(",")):
                i = int(idx)
                if i < len(ldims):
                    k *= ldims[i]
    return 2.0 * out * k


def _instr_bytes(comp: Computation, ins: Instr) -> float:
    total = float(_type_bytes(ins.rtype))
    for op in ins.operands:
        t = comp.symbol(op)
        if t is not None:
            total += _type_bytes(t)
    return total


def _flops_only(comp: Computation, comps) -> float:
    """dot flops inside a fusion body (bytes don't count there)."""
    total = 0.0
    for ins in comp.instrs:
        if ins.opcode == "dot":
            total += _dot_flops(comp, ins)
        elif ins.opcode == "fusion":
            for c in _CALL_RE.findall(ins.rest):
                if c in comps:
                    total += _flops_only(comps[c], comps)
    return total


def analyze_computation(comp: Computation, comps: Dict[str, Computation],
                        memo: Dict[str, Costs]) -> Costs:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = Costs()  # cycle guard
    total = Costs()
    for ins in comp.instrs:
        op = ins.opcode
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES:
            b = float(_type_bytes(ins.rtype)) * _WIRE_FACTOR[base]
            total.wire[base] += b
            total.coll_counts[base] += 1
            total._acc(base, _instr_bytes(comp, ins))
            continue
        if op == "dot":
            total.flops += _dot_flops(comp, ins)
            total._acc("dot", _instr_bytes(comp, ins))
            continue
        if op == "fusion":
            b = _instr_bytes(comp, ins)
            label = "fusion"
            for c in _CALL_RE.findall(ins.rest):
                if c in comps:
                    total.flops += _flops_only(comps[c], comps)
                    root = comps[c].instrs[-1] if comps[c].instrs else None
                    if root is not None and root.opcode in (
                            "dynamic-update-slice", "scatter"):
                        # In-place update: traffic is the updated slice,
                        # not the full buffer. Drop the result + the
                        # aliased full-size operand; what remains is the
                        # update payload (+ indices).
                        rb = float(_type_bytes(ins.rtype))
                        opb = sorted((float(_type_bytes(comp.symbol(o)))
                                      for o in ins.operands
                                      if comp.symbol(o) is not None),
                                     reverse=True)
                        b -= rb + (opb[0] if opb else 0.0)
                        b = max(b, 0.0)
                        label = "inplace-update"
            total._acc(label, b)
            continue
        if op == "while":
            trip = 1.0
            mt = _TRIP_RE.search(ins.rest)
            if mt:
                trip = float(mt.group(1))
            for c in _CALL_RE.findall(ins.rest):
                if c in comps:
                    total.add(analyze_computation(comps[c], comps, memo),
                              trip)
            continue
        if op in ("call", "custom-call", "conditional", "async-start"):
            total._acc(op, _instr_bytes(comp, ins))
            names = _CALL_RE.findall(ins.rest)
            mb = _BRANCH_RE.search(ins.rest)
            if mb:
                names += [n.strip().lstrip("%")
                          for n in mb.group(1).split(",")]
            for c in names:
                if c in comps:
                    total.add(analyze_computation(comps[c], comps, memo))
            continue
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all"):
            continue  # no HBM traffic of their own
        if op in ("reduce", "sort", "scatter"):
            total._acc(op, _instr_bytes(comp, ins))
            continue
        # generic unfused op
        total._acc(op, _instr_bytes(comp, ins))
    memo[comp.name] = total
    return total


def analyze_text(text: str) -> Costs:
    comps, entry = parse_hlo(text)
    if entry is None or entry not in comps:
        return Costs()
    return analyze_computation(comps[entry], comps, {})
