"""Serving launcher: generation or retrieval-augmented serving.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --mode generate --batch 4 --prompt-len 32 --max-new 16
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --mode retrieval --corpus 4096 --queries 64
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", choices=("generate", "retrieval"),
                    default="generate")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--corpus", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--radius", type=float, default=0.3)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced_config
    from repro.data import lm_batch
    from repro.models import init_params
    from repro.models.parallel import ParallelConfig
    from repro.serve import RetrievalConfig, RetrievalService, generate

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    par = ParallelConfig(mesh=None, attn_chunk_q=64, attn_chunk_k=64,
                         logits_chunk=128)
    params = init_params(cfg, jax.random.PRNGKey(0))

    if args.mode == "generate":
        batch = lm_batch(0, 0, batch=args.batch, seq=args.prompt_len,
                         vocab=cfg.vocab, cfg=cfg)
        batch.pop("labels")
        toks = generate(params, batch, cfg, par,
                        cache_len=args.prompt_len + args.max_new,
                        max_new_tokens=args.max_new)
        print("generated:", toks.shape)
        print(toks[:2])
    else:
        svc = RetrievalService(cfg, par, params,
                               RetrievalConfig(radius=args.radius))
        corpus_batches = []
        bs = 64
        for i in range(args.corpus // bs):
            b = lm_batch(1, i, batch=bs, seq=args.prompt_len,
                         vocab=cfg.vocab, cfg=cfg)
            b.pop("labels")
            corpus_batches.append(b)
        n = svc.index_corpus(corpus_batches)
        qb = lm_batch(2, 0, batch=args.queries, seq=args.prompt_len,
                      vocab=cfg.vocab, cfg=cfg)
        qb.pop("labels")
        res, _ = svc.query(qb)
        sizes = [len(res.neighbors(i)) for i in range(res.n_queries)]
        print(f"indexed {n} docs; {args.queries} queries; "
              f"mean output size {sum(sizes)/len(sizes):.1f}; "
              f"frac linear {res.frac_linear:.2f}")
        print("service stats:", svc.stats)


if __name__ == "__main__":
    main()
