import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must precede any jax import (device count locks at first init).

"""Dry-run of the PAPER'S OWN workload on the production mesh: the
distributed hybrid query (Algorithm 2 with pmax-merged HLLs and
per-shard routing) over a 134M-vector corpus, lowered + compiled for
the 16x16 (and optionally 2x16x16) mesh with abstract inputs.

  PYTHONPATH=src python -m repro.launch.dryrun_retrieval [--multi-pod]

This proves the retrieval layer itself (not just the LM cells) shards:
the candSize estimate is one (Q, m) pmax; collisions one (Q,) psum;
each shard routes independently and reports a fixed-size union slice.
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.cost_model import CostModel
from repro.core.distributed import ShardedIndexState, make_query_fn
from repro.core.lsh import make_family
from repro.launch import hlo_analysis
from repro.launch import roofline as rl
from repro.launch.dryrun import RESULTS_DIR, _mem_stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--n-total", type=int, default=1 << 27)  # 134M vectors
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument("--queries", type=int, default=1024)
    args = ap.parse_args()

    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    # flatten pod+data into the index's data axis if multi-pod
    data_axis = "data"
    shards = mesh.shape[data_axis]
    chips = 1
    for s in mesh.shape.values():
        chips *= s

    n, d, q = args.n_total, args.d, args.queries
    n_local = n // shards
    L, B, m, cap, max_out = 20, 1 << 18, 64, 128, 256
    fam = make_family("cosine", d=d, L=L, r=0.3, delta=0.1)
    params = jax.eval_shape(lambda: fam.init(jax.random.PRNGKey(0)))

    sds = jax.ShapeDtypeStruct
    state = ShardedIndexState(
        x=sds((n, d), jnp.float32),
        perm=sds((shards, L, n_local), jnp.int32),
        starts=sds((shards, L, B + 1), jnp.int32),
        registers=sds((shards, L, B, m), jnp.uint8),
    )
    queries = sds((q, d), jnp.float32)

    qfn = make_query_fn(fam, num_buckets=B, mesh=mesh, n_total=n,
                        cost_model=CostModel(1.0, 10.0), metric="cosine",
                        cap=cap, max_out=max_out, policy="per_shard")

    sh = lambda *spec: NamedSharding(mesh, P(*spec))
    state_sh = ShardedIndexState(
        x=sh(data_axis), perm=sh(data_axis), starts=sh(data_axis),
        registers=sh(data_axis))
    params_sh = jax.tree_util.tree_map(lambda _: sh(), params)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(
            lambda st, pa, qq: qfn(st, pa, qq, 0.3),
            in_shardings=(state_sh, params_sh, sh()),
        ).lower(state, params, queries)
        compiled = lowered.compile()
    dt = time.time() - t0

    mem = _mem_stats(compiled)
    costs = hlo_analysis.analyze_text(compiled.as_text())
    wire = sum(costs.wire.values())
    terms = rl.terms_from_cost(
        {"flops": costs.flops, "bytes accessed": costs.bytes}, wire,
        2.0 * q * n * d, chips)  # useful = one full scan equivalent
    rec = {
        "arch": "paper-hybrid-lsh-index", "shape": f"n={n},d={d},Q={q}",
        "mesh": "2x16x16" if args.multi_pod else "16x16", "tag": "",
        "status": "ok", "chips": chips, "compile_s": round(dt, 1),
        "memory": mem,
        "cost": {"flops": costs.flops, "bytes accessed": costs.bytes},
        "collectives": dict(costs.wire),
        "terms": {"compute_s": terms.compute_s,
                  "memory_s": terms.memory_s,
                  "collective_s": terms.collective_s,
                  "dominant": terms.dominant},
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(
        RESULTS_DIR,
        f"paper-index__retrieval__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec["terms"], indent=1))
    print("memory/dev GiB:",
          mem.get("total_bytes_per_device", 0) / 2**30)
    print("compile_s:", dt)


if __name__ == "__main__":
    main()
