"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
      --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

On a real cluster this process runs once per host with
jax.distributed.initialize() (call guarded behind --coordinator); in
this container it runs single-process.  Restart-after-crash resumes
from the latest committed checkpoint automatically.
"""
from __future__ import annotations

import argparse
import logging
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--coordinator", default=None,
                    help="host:port for jax.distributed (cluster mode)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host platform devices (debug mesh)")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    import jax
    if args.coordinator:
        jax.distributed.initialize(coordinator_address=args.coordinator)

    from repro.configs import get_config, reduced_config
    from repro.models.parallel import ParallelConfig
    from repro.train import LoopConfig, TrainConfig, train_loop

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)

    if args.devices:
        from repro.launch.mesh import make_debug_mesh
        n = args.devices
        mesh = make_debug_mesh((n // 2, 2), ("data", "model"))
        par = ParallelConfig(mesh=mesh, data_axes=("data",),
                             attn_chunk_q=min(128, args.seq),
                             attn_chunk_k=min(128, args.seq),
                             logits_chunk=min(512, args.seq))
    else:
        par = ParallelConfig(mesh=None, attn_chunk_q=min(128, args.seq),
                             attn_chunk_k=min(128, args.seq),
                             logits_chunk=min(512, args.seq))

    hist = train_loop(
        cfg, par, batch=args.batch, seq=args.seq,
        tcfg=TrainConfig(peak_lr=args.lr, total_steps=args.steps,
                         warmup_steps=max(1, args.steps // 10),
                         microbatch=args.microbatch),
        lcfg=LoopConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir))
    print("final loss:", hist["loss"][-1] if hist["loss"] else None)


if __name__ == "__main__":
    main()
