"""Per-(arch x shape x mesh) parallelism policy + abstract inputs.

``input_specs`` returns ShapeDtypeStruct stand-ins for every input of
the lowered step — weights, optimizer state, KV caches, token batches —
so the dry-run lowers/compiles with ZERO device allocation.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import cache_specs, init_caches, init_params, param_specs
from repro.models.parallel import ParallelConfig
from repro.train.step import TrainConfig, batch_specs, init_state, state_specs


def make_par(mesh: Mesh, multi_pod: bool, cfg: ArchConfig,
             shape: ShapeSpec, **overrides) -> ParallelConfig:
    """The sharding policy for one dry-run cell (see DESIGN.md §5)."""
    daxes = ("pod", "data") if multi_pod else ("data",)
    n_batch_shards = 1
    for a in daxes:
        n_batch_shards *= mesh.shape[a]

    kw: Dict[str, Any] = dict(mesh=mesh, data_axes=daxes, seq_shard=True,
                              fsdp=True, remat="block")
    if shape.kind == "decode":
        kw["remat"] = "none"
        if shape.global_batch >= n_batch_shards:
            # batch over data axes, cache seq over model axis
            kw["decode_seq_shard"] = ("model",)
        else:
            # global_batch=1 (long_500k): replicate batch, shard the
            # cache sequence over EVERY axis; fsdp still on data axes.
            kw["batch_axes"] = ()
            kw["decode_seq_shard"] = daxes + ("model",)
    elif shape.kind == "prefill":
        kw["remat"] = "none"
    kw.update(overrides)
    return ParallelConfig(**kw)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _batch_struct(cfg: ArchConfig, b: int, s: int, with_labels: bool):
    out = {"tokens": _sds((b, s), jnp.int32)}
    if with_labels:
        out["labels"] = _sds((b, s), jnp.int32)
    if cfg.encoder_layers:
        out["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.num_image_tokens:
        out["image_embeds"] = _sds((b, cfg.num_image_tokens, cfg.d_model),
                                   jnp.bfloat16)
    return out


def abstract_state(cfg: ArchConfig, tcfg: TrainConfig = TrainConfig()):
    return jax.eval_shape(
        lambda: init_state(cfg, jax.random.PRNGKey(0), tcfg))


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def abstract_caches(cfg: ArchConfig, b: int, cache_len: int,
                    par: ParallelConfig):
    mem_len = cfg.encoder_seq or cfg.num_image_tokens
    return jax.eval_shape(
        lambda: init_caches(cfg, b, cache_len, par, memory_len=mem_len))


def to_shardings(abstract_tree, spec_tree, mesh: Mesh):
    """Map spec tuples onto the abstract tree's structure.

    tree_map flattens ``spec_tree`` *up to* the abstract tree's treedef,
    so tuple spec entries land intact at array-leaf positions even
    though tuples are also used as containers ("blocks").
    """
    return jax.tree_util.tree_map(
        lambda a, s: NamedSharding(mesh, P(*s)), abstract_tree, spec_tree)


def input_specs(cfg: ArchConfig, shape: ShapeSpec, par: ParallelConfig,
                tcfg: TrainConfig = TrainConfig()):
    """(args, in_shardings, out_shardings_hint) for the cell's step fn."""
    mesh = par.mesh
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        st = abstract_state(cfg, tcfg)
        ba = _batch_struct(cfg, b, s, with_labels=True)
        st_sh = to_shardings(st, state_specs(cfg, par, tcfg), mesh)
        ba_sh = to_shardings(ba, batch_specs(cfg, par), mesh)
        return (st, ba), (st_sh, ba_sh), (st_sh, None)
    if shape.kind == "prefill":
        pa = abstract_params(cfg)
        ba = _batch_struct(cfg, b, s, with_labels=False)
        pa_sh = to_shardings(pa, param_specs(cfg, par), mesh)
        bspec = {"tokens": (par.batch(), None)}
        if cfg.encoder_layers:
            bspec["frames"] = (par.batch(), None, None)
        if cfg.num_image_tokens:
            bspec["image_embeds"] = (par.batch(), None, None)
        ba_sh = to_shardings(ba, bspec, mesh)
        ca = abstract_caches(cfg, b, s, par)
        ca_sh = to_shardings(ca, cache_specs(cfg, par), mesh)
        tok_sh = NamedSharding(mesh, P(par.batch()))
        return (pa, ba), (pa_sh, ba_sh), (tok_sh, ca_sh, tok_sh)
    # decode
    pa = abstract_params(cfg)
    ca = abstract_caches(cfg, b, s, par)
    tok = _sds((b,), jnp.int32)
    lens = _sds((b,), jnp.int32)
    pa_sh = to_shardings(pa, param_specs(cfg, par), mesh)
    ca_sh = to_shardings(ca, cache_specs(cfg, par), mesh)
    tok_sh = NamedSharding(mesh, P(par.batch()))
    return ((pa, ca, tok, lens), (pa_sh, ca_sh, tok_sh, tok_sh),
            (tok_sh, ca_sh, tok_sh))
