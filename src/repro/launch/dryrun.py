import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import anywhere: jax locks
# the device count at first initialization.  Only the dry-run gets 512
# placeholder devices; tests/benchmarks see the real single device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell,
print memory_analysis / cost_analysis, extract collective bytes, and
cache everything as JSON for the roofline report.

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Failures (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system; --all records them per-cell and exits non-zero.
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCH_NAMES, SHAPES, get_config, shape_applicable
from repro.launch import hlo_analysis
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, make_par
from repro.serve.engine import make_serve_prefill, make_serve_step
from repro.train.step import TrainConfig, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _mem_stats(compiled) -> Dict[str, float]:
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes", "host_temp_size_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_bytes_per_device"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0))
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: Optional[dict] = None,
             tag: str = "") -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "tag": tag, "status": "skipped", "reason": reason}
    if not ok:
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for s in mesh.shape.values():
        chips *= s
    par = make_par(mesh, multi_pod, cfg, shape, **(overrides or {}))
    tcfg = TrainConfig()
    (args, in_sh, out_sh) = input_specs(cfg, shape, par, tcfg)

    if shape.kind == "train":
        fn = make_train_step(cfg, par, tcfg)
        donate = (0,)
    elif shape.kind == "prefill":
        fn = make_serve_prefill(cfg, par, cache_len=shape.seq_len)
        donate = ()
    else:
        fn = make_serve_step(cfg, par)
        donate = (1,)

    # Analytic per-device residency of the step's inputs (weights, opt
    # state, caches, batch) from shard shapes — independent check on
    # memory_analysis, exact by construction.
    import numpy as np

    def _leaf_bytes(a, sh):
        shard = sh.shard_shape(a.shape)
        return int(np.prod(shard)) * a.dtype.itemsize

    args_bytes = sum(jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(_leaf_bytes, args, in_sh)))

    t0 = time.time()
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = _mem_stats(compiled)
    xla_cost = compiled.cost_analysis() or {}
    xla_cost = {k: float(v) for k, v in xla_cost.items()
                if isinstance(v, (int, float)) and k in ("flops",
                                                         "bytes accessed")}
    # Loop-aware re-analysis: XLA's cost_analysis counts while bodies
    # once; scan-over-layers models need trip-count multipliers.
    hlo = compiled.as_text()
    costs = hlo_analysis.analyze_text(hlo)
    wire = sum(costs.wire.values())
    mf = rl.model_flops(cfg, shape)
    terms = rl.terms_from_cost(
        {"flops": costs.flops, "bytes accessed": costs.bytes}, wire, mf,
        chips)

    rec.update({
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem,
        "input_bytes_per_device": args_bytes,
        "cost": {"flops": costs.flops, "bytes accessed": costs.bytes},
        "xla_cost_loop_body_once": xla_cost,
        "collectives": dict(costs.wire),
        "collective_counts": dict(costs.coll_counts),
        "bytes_by_op": {k: round(v) for k, v in sorted(
            costs.by_op.items(), key=lambda kv: -kv[1])[:12]},
        "terms": {
            "compute_s": terms.compute_s, "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "model_flops_global": mf,
            "useful_flops_ratio": terms.useful_flops_ratio,
            "roofline_fraction": terms.roofline_fraction,
        },
        "params": cfg.num_params(),
        "active_params": cfg.num_active_params(),
    })
    return rec


def cell_path(arch: str, shape: str, mesh_name: str, tag: str = "") -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(RESULTS_DIR,
                        f"{arch}__{shape}__{mesh_name}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute cached cells")
    ap.add_argument("--tag", default="", help="variant tag (perf iters)")
    ap.add_argument("--override", default="",
                    help="k=v[,k=v] ParallelConfig overrides")
    args = ap.parse_args()

    overrides = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        overrides[k] = {"true": True, "false": False}.get(
            v.lower(), v if not v.isdigit() else int(v))

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = ([(a, s) for a in ARCH_NAMES for s in SHAPES]
             if args.all else [(args.arch, args.shape)])

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            path = cell_path(arch, shape, mesh_name, args.tag)
            if os.path.exists(path) and not args.force:
                print(f"[cache] {arch} {shape} {mesh_name}")
                continue
            print(f"[run]   {arch} {shape} {mesh_name} ...", flush=True)
            try:
                rec = run_cell(arch, shape, mp, overrides, args.tag)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "tag": args.tag, "status": "error",
                       "error": f"{type(e).__name__}: {e}"}
                failures += 1
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec["status"] == "ok":
                t = rec["terms"]
                print(f"  ok: compile={rec['compile_s']}s "
                      f"mem/dev={rec['memory'].get('total_bytes_per_device', 0)/2**30:.2f}GiB "
                      f"dominant={t['dominant']} "
                      f"roofline={t['roofline_fraction']:.3f}", flush=True)
            elif rec["status"] == "skipped":
                print(f"  skipped: {rec['reason']}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
