"""Production meshes.  Functions, not module constants — importing this
module must never touch jax device state (the dry-run sets
XLA_FLAGS before any jax initialization)."""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips with a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape: Tuple[int, ...] = (2, 2),
                    axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for subprocess tests (XLA_FLAGS host device count)."""
    return jax.make_mesh(shape, axes)


def data_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)
