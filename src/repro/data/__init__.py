from repro.data.synthetic import (LMDataIterator, clustered_dataset,
                                  lm_batch, paper_dataset, query_split)

__all__ = ["LMDataIterator", "clustered_dataset", "lm_batch",
           "paper_dataset", "query_split"]
