"""Deterministic, shard-aware, resumable synthetic data.

Two generators:

  * LM token batches — pure function of (seed, step): restart-safe by
    construction (the train loop just replays the step counter), and
    each host can slice its addressable shard without coordination.
  * Clustered vector datasets for the paper's r-NN experiments —
    Gaussian mixtures with a controllable "dense core" so query sets
    contain the hard queries of the paper's Fig. 1/Webspam discussion.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------------ LM
def lm_batch(seed: int, step: int, *, batch: int, seq: int, vocab: int,
             cfg=None) -> Dict[str, jax.Array]:
    """Deterministic token batch for (seed, step)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    toks = jax.random.randint(key, (batch, seq + 1), 0, vocab, jnp.int32)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg is not None and getattr(cfg, "encoder_layers", 0):
        kf = jax.random.fold_in(key, 1)
        out["frames"] = jax.random.normal(
            kf, (batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg is not None and getattr(cfg, "num_image_tokens", 0):
        ki = jax.random.fold_in(key, 2)
        out["image_embeds"] = jax.random.normal(
            ki, (batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    return out


@dataclasses.dataclass
class LMDataIterator:
    """Resumable iterator: ``state`` is just the step counter."""

    seed: int
    batch: int
    seq: int
    vocab: int
    step: int = 0
    cfg: Optional[object] = None

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        b = lm_batch(self.seed, self.step, batch=self.batch, seq=self.seq,
                     vocab=self.vocab, cfg=self.cfg)
        self.step += 1
        return b

    def state_dict(self):
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, s):
        assert s["seed"] == self.seed, "data seed changed across restart"
        self.step = int(s["step"])


# ------------------------------------------------- r-NN vector datasets
def clustered_dataset(n: int, d: int, *, n_clusters: int = 32,
                      dense_core_frac: float = 0.0,
                      core_scale: float = 0.05, cluster_scale: float = 0.25,
                      seed: int = 0, metric: str = "l2") -> np.ndarray:
    """Mixture-of-Gaussians points; optionally a tight "dense core".

    ``dense_core_frac`` > 0 reproduces the paper's Webspam regime: a
    fraction of the dataset sits in one tiny cluster, so queries landing
    there have near-n output sizes and LSH loses to linear search.
    """
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    n_core = int(n * dense_core_frac)
    n_rest = n - n_core
    assign = rng.integers(0, n_clusters, n_rest)
    pts = centers[assign] + cluster_scale * rng.normal(
        size=(n_rest, d)).astype(np.float32)
    if n_core:
        core = centers[0] + core_scale * rng.normal(
            size=(n_core, d)).astype(np.float32)
        pts = np.concatenate([pts, core], axis=0)
        rng.shuffle(pts, axis=0)
    if metric == "cosine":
        pts /= np.maximum(np.linalg.norm(pts, axis=1, keepdims=True), 1e-9)
    return pts.astype(np.float32)


def paper_dataset(name: str, scale: float = 1.0, seed: int = 0):
    """Synthetic analogues of the paper's four datasets.

    Matched (n, d, metric); density skew approximates each dataset's
    character (Webspam gets the dense core that makes hybrid win).
    Returns (points, metric).  ``scale`` shrinks n for CI-speed runs.
    """
    presets = {
        "corel": dict(n=68040, d=32, metric="l2", n_clusters=64,
                      dense_core_frac=0.02),
        "covertype": dict(n=581012, d=54, metric="l1", n_clusters=16,
                          dense_core_frac=0.05),
        "webspam": dict(n=350000, d=254, metric="cosine", n_clusters=32,
                        dense_core_frac=0.25, core_scale=0.02),
        "mnist": dict(n=60000, d=780, metric="hamming"),
    }
    p = dict(presets[name])
    metric = p.pop("metric")
    p["n"] = max(1024, int(p["n"] * scale))
    if metric == "hamming":
        # 64-bit SimHash fingerprints of clustered real vectors, as the
        # paper does for MNIST.
        base = clustered_dataset(p["n"], p["d"], n_clusters=10, seed=seed)
        rng = np.random.default_rng(seed + 1)
        proj = rng.normal(size=(p["d"], 64)).astype(np.float32)
        bits = (base @ proj > 0)
        words = np.zeros((p["n"], 2), np.uint32)
        for w in range(2):
            for j in range(32):
                words[:, w] |= bits[:, w * 32 + j].astype(
                    np.uint32) << np.uint32(j)
        return words, metric
    pts = clustered_dataset(seed=seed, metric=metric, **p)
    return pts, metric


def query_split(x: np.ndarray, n_queries: int = 100, seed: int = 0):
    """Paper protocol: randomly remove n_queries points as the query set."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(x.shape[0])
    q, rest = idx[:n_queries], idx[n_queries:]
    return x[rest], x[q]
