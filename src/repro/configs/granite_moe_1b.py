"""granite-moe-1b-a400m [moe] — hf:ibm-granite/granite-3.0-1b-a400m-base.

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert, 32 experts top-8,
vocab=49155 (padded to 49408 for sharding).
"""
from repro.configs.base import MOE, ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
    vocab=49408,  # true 49155, padded for sharding
    pattern=(MOE,), repeats=24,
    moe=MoESpec(num_experts=32, top_k=8, capacity_factor=1.25),
    mlp_act="silu", rope_theta=1e4, supports_long_context=False,
)
