"""falcon-mamba-7b [ssm] — arXiv:2410.05355 (Mamba-1, attention-free).

64L d_model=4096, ssm_state=16, expand=2 (d_inner 8192), vocab=65024.
d_ff=0: there is no MLP — each layer is one Mamba mixer.
long_500k RUNS (O(1) decode state).
"""
from repro.configs.base import MAMBA1, ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=0,
    vocab=65024, pattern=(MAMBA1,), repeats=64,
    ssm=SSMSpec(d_state=16, version=1, expand=2, d_conv=4, chunk=64),
    supports_long_context=True,
)
