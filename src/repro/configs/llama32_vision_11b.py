"""llama-3.2-vision-11b [vlm] — hf:meta-llama/Llama-3.2-11B-Vision.

40L text backbone d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256,
cross-attention to image patch embeddings every 5th layer.  The vision
tower is a STUB: input_specs feeds precomputed patch embeddings
(B, 1536, 4096).  Full attention -> long_500k skipped.
"""
from repro.configs.base import ATTN, CROSS, ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128256, head_dim=128,
    pattern=(ATTN, ATTN, ATTN, ATTN, CROSS), repeats=8,
    num_image_tokens=1536, mlp_act="silu", rope_theta=5e5,
    supports_long_context=False,
)
