"""mistral-nemo-12b [dense] — hf:mistralai/Mistral-Nemo-Base-2407.

40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=131072,
128k context (RoPE theta 1e6), full attention -> long_500k skipped.
"""
from repro.configs.base import ATTN, ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=131072, head_dim=128, pattern=(ATTN,), repeats=40,
    mlp_act="silu", rope_theta=1e6, supports_long_context=False,
)
