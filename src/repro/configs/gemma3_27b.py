"""gemma3-27b [dense] — hf:google/gemma-3-* family scaled per assignment.

62L d_model=5376 32H (GQA kv=16, head_dim=128) d_ff=21504 vocab=262144,
5 local (sliding window 1024) : 1 global pattern, 128k context.
long_500k RUNS: 52/62 layers are windowed (ring caches); the 10 global
layers decode with a seq-sharded flash-decode.
"""
from repro.configs.base import ATTN, SWA, ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504,
    vocab=262144, head_dim=128,
    pattern=(SWA, SWA, SWA, SWA, SWA, ATTN), repeats=10, tail=(SWA, SWA),
    sliding_window=1024, mlp_act="silu", rope_theta=1e6,
    supports_long_context=True,
)
