"""nemotron-4-15b [dense] — arXiv:2402.16819.

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000,
squared-ReLU MLP; full attention -> long_500k skipped.
"""
from repro.configs.base import ATTN, ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=24576,
    vocab=256000, pattern=(ATTN,), repeats=32,
    mlp_act="relu2", rope_theta=1e4, supports_long_context=False,
)
