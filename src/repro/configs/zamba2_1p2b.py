"""zamba2-1.2b [hybrid] — arXiv:2411.15242.

38 blocks d_model=2048: Mamba-2 (ssm_state=64) backbone with a SHARED
(weight-tied) full-attention block every 6th position.
32H kv=32, d_ff=8192 (shared block MLP), vocab=32000.
long_500k RUNS (SSM state O(1); shared-attn KV seq-sharded).
"""
from repro.configs.base import MAMBA2, SHARED_ATTN, ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000,
    pattern=(MAMBA2, MAMBA2, MAMBA2, MAMBA2, MAMBA2, SHARED_ATTN),
    repeats=6, tail=(MAMBA2, MAMBA2),
    ssm=SSMSpec(d_state=64, version=2, expand=2, d_conv=4, head_dim=64,
                chunk=64),
    mlp_act="silu", rope_theta=1e4, supports_long_context=True,
)
