"""Config schema: architectures, input shapes, parallelism.

Every assigned architecture is one ``ArchConfig`` in
``src/repro/configs/<id>.py``; the dry-run/launchers select them with
``--arch <id>``.  A model is assembled from a *block pattern*: a short
static list of layer descriptors compiled inline, scanned ``repeats``
times, plus an optional unstacked ``tail`` — this keeps HLO size (and
compile time) independent of depth and expresses heterogeneous stacks
(gemma3's 5 local : 1 global, zamba2's mamba2 + shared-attention).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

# Layer kinds usable in a block pattern.
ATTN = "attn"                # global causal self-attention + MLP
SWA = "swa"                  # sliding-window causal self-attention + MLP
MOE = "moe"                  # global attention + MoE MLP
MAMBA1 = "mamba1"            # Mamba-1 selective-scan block
MAMBA2 = "mamba2"            # Mamba-2 (SSD) block
SHARED_ATTN = "shared_attn"  # weight-tied global attention block (zamba2)
CROSS = "cross_attn"         # causal self-attn + cross-attn + MLP (vlm/encdec)


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int
    version: int = 1           # 1 = Mamba-1, 2 = Mamba-2 (SSD)
    expand: int = 2
    d_conv: int = 4
    head_dim: int = 64         # Mamba-2 only
    dt_rank: int = 0           # 0 -> ceil(d_model / 16) (Mamba-1 default)
    chunk: int = 64            # chunked-scan length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | ssm | moe | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # Block pattern (see module docstring). Must satisfy
    # len(pattern) * repeats + len(tail) == n_layers.
    pattern: Tuple[str, ...] = (ATTN,)
    repeats: int = 0           # 0 -> n_layers // len(pattern)
    tail: Tuple[str, ...] = ()

    head_dim: int = 0          # 0 -> d_model // n_heads
    mlp_act: str = "silu"      # silu (gated) | relu2 (squared ReLU, gated)
    rope_theta: float = 1e6
    sliding_window: int = 1024  # window for SWA layers
    norm_eps: float = 1e-5

    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None

    # Modality stubs (precomputed embeddings fed via input_specs).
    encoder_layers: int = 0    # whisper-style bidirectional encoder
    encoder_seq: int = 0       # stub frame/patch sequence length
    num_image_tokens: int = 0  # vlm cross-attention memory length

    supports_long_context: bool = False  # run long_500k?
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- derived -----------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_repeats(self) -> int:
        r = self.repeats or (self.n_layers // len(self.pattern))
        assert len(self.pattern) * r + len(self.tail) == self.n_layers, (
            self.name, len(self.pattern), r, len(self.tail), self.n_layers)
        return r

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def num_params(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        hd, H, Hkv = self.hd, self.n_heads, self.n_kv_heads
        attn = D * H * hd + 2 * D * Hkv * hd + H * hd * D
        mlp = 3 * D * F  # gated
        total = 2 * V * D  # embed + lm_head
        layers = list(self.pattern) * self.n_repeats + list(self.tail)
        for kind in layers:
            if kind in (ATTN, SWA, SHARED_ATTN):
                total += attn + mlp
            elif kind == CROSS:
                total += 2 * attn + mlp
            elif kind == MOE:
                total += attn + self.moe.num_experts * 3 * D * F \
                    + D * self.moe.num_experts
            elif kind in (MAMBA1, MAMBA2):
                di = self.ssm.expand * D
                n = self.ssm.d_state
                if self.ssm.version == 1:
                    dtr = self.ssm.dt_rank or -(-D // 16)
                    total += 2 * D * di + di * (dtr + 2 * n) + dtr * di \
                        + di * n + di * D
                else:
                    nh = di // self.ssm.head_dim
                    total += D * (2 * di + 2 * n + nh) + di * D
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp)
        if self.num_image_tokens:
            total += D * D  # image projection stub
        return int(total)

    def num_active_params(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.num_params()
        total = self.num_params()
        layers = list(self.pattern) * self.n_repeats + list(self.tail)
        n_moe = sum(1 for k in layers if k == MOE)
        dense_share = self.moe.top_k / self.moe.num_experts
        expert_params = n_moe * self.moe.num_experts * 3 * self.d_model * self.d_ff
        return int(total - expert_params * (1.0 - dense_share))


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason).  long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: no sub-quadratic path for "
                       "a 524288-token context (see DESIGN.md skips)")
    return True, ""
