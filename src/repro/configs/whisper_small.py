"""whisper-small [audio] — arXiv:2212.04356 (enc-dec backbone only).

12L enc + 12L dec, d_model=768 12H d_ff=3072 vocab=51865 (padded to
51872 for 16-way vocab sharding).  The conv audio frontend is a STUB:
input_specs feeds precomputed frame embeddings (B, 1536, 768).
Enc-dec (has a decoder) -> decode_32k runs; full attention ->
long_500k skipped.
"""
from repro.configs.base import CROSS, ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51872,  # true 51865, padded for sharding
    pattern=(CROSS,), repeats=12,
    encoder_layers=12, encoder_seq=1536,  # stub frames (paper: 1500)
    mlp_act="silu", rope_theta=1e4, supports_long_context=False,
)
