"""llama4-maverick-400b-a17b [moe] — hf:meta-llama/Llama-4 family.

48L d_model=5120 40H (GQA kv=8) d_ff=8192/expert, 128 experts top-1,
vocab=202048.  All-MoE layers per assignment; full attention ->
long_500k skipped.
"""
from repro.configs.base import MOE, ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, head_dim=128, pattern=(MOE,), repeats=48,
    moe=MoESpec(num_experts=128, top_k=1, capacity_factor=1.25),
    mlp_act="silu", rope_theta=5e5, supports_long_context=False,
)
