"""yi-6b [dense] — arXiv:2403.04652 (llama-arch GQA).

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ATTN, ArchConfig

CONFIG = ArchConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008,
    vocab=64000, pattern=(ATTN,), repeats=32,
    mlp_act="silu", rope_theta=5e6, supports_long_context=False,
)
