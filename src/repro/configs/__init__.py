"""Architecture registry: ``--arch <id>`` resolution for launchers."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import (ArchConfig, ShapeSpec, SHAPES,
                                shape_applicable)

_MODULES = {
    "mistral-nemo-12b": "mistral_nemo_12b",
    "nemotron-4-15b": "nemotron_4_15b",
    "yi-6b": "yi_6b",
    "gemma3-27b": "gemma3_27b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-small": "whisper_small",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "zamba2-1.2b": "zamba2_1p2b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def reduced_config(cfg: ArchConfig, *, d_model: int = 64,
                   n_layers: int | None = None, vocab: int = 512,
                   d_ff: int = 128) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests.

    Keeps the block pattern (one repeat + tail) and all structural
    features (GQA ratio, MoE top-k, SSM version, cross-attn) while
    shrinking every width.
    """
    pat = cfg.pattern
    n_rep = 1
    layers = len(pat) * n_rep + len(cfg.tail)
    heads = max(2, min(cfg.n_heads, 4))
    kv = max(1, heads * cfg.n_kv_heads // cfg.n_heads)
    changes = dict(
        n_layers=layers, d_model=d_model, n_heads=heads, n_kv_heads=kv,
        d_ff=d_ff if cfg.d_ff else 0, vocab=vocab, head_dim=0,
        repeats=n_rep, sliding_window=8,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2))
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=8, head_dim=16, chunk=8)
    if cfg.encoder_layers:
        changes["encoder_layers"] = 2
        changes["encoder_seq"] = 16
    if cfg.num_image_tokens:
        changes["num_image_tokens"] = 16
    return dataclasses.replace(cfg, **changes)


__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "ARCH_NAMES", "get_config",
           "reduced_config", "shape_applicable"]
