"""Fault-tolerant training loop.

Responsibilities:
  * restore-from-latest-committed checkpoint on (re)start — a crashed
    run relaunches with the same command and resumes (tested by
    killing/restarting in tests/test_fault.py);
  * periodic async checkpointing (two-phase commit in CheckpointManager);
  * deterministic data resume (iterator state = step counter);
  * straggler watchdog: per-step wall time vs a running median — slow
    steps are logged with the step index (on a real cluster this feeds
    the controller that evicts/replaces the slow host; here the hook
    records them, and tests inject artificial delay);
  * failure injection hook for tests (raise at step N).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data import LMDataIterator
from repro.models.parallel import ParallelConfig
from repro.train.step import (TrainConfig, init_state, make_jitted_train_step,
                              state_specs)

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    data_seed: int = 0
    straggler_factor: float = 3.0


def train_loop(cfg: ArchConfig, par: ParallelConfig, *, batch: int, seq: int,
               tcfg: TrainConfig = TrainConfig(),
               lcfg: LoopConfig = LoopConfig(),
               failure_injector: Optional[Callable[[int], None]] = None,
               step_delay_injector: Optional[Callable[[int], float]] = None,
               ) -> Dict[str, list]:
    """Returns history dict (loss per logged step, straggler events)."""
    step_fn = make_jitted_train_step(cfg, par, tcfg)
    data = LMDataIterator(seed=lcfg.data_seed, batch=batch, seq=seq,
                          vocab=cfg.vocab, cfg=cfg)

    mgr = CheckpointManager(lcfg.ckpt_dir) if lcfg.ckpt_dir else None
    state = init_state(cfg, jax.random.PRNGKey(0), tcfg)
    start_step = 0
    if mgr is not None and mgr.latest_step() is not None:
        restored, ck_step = mgr.restore({"state": state,
                                         "data": data.state_dict()})
        state = restored["state"]
        data.load_state_dict(restored["data"])
        start_step = ck_step
        log.info("restored checkpoint at step %d", start_step)

    if par.active:
        from jax.sharding import NamedSharding, PartitionSpec as P
        shardings = jax.tree_util.tree_map(
            lambda a, s: NamedSharding(par.mesh, P(*s)), state,
            state_specs(cfg, par, tcfg))
        state = jax.tree_util.tree_map(jax.device_put, state, shardings)

    history = {"loss": [], "step": [], "stragglers": []}
    times = []
    for step in range(start_step, lcfg.steps):
        if failure_injector is not None:
            failure_injector(step)
        batch_data = next(data)
        t0 = time.perf_counter()
        if step_delay_injector is not None:
            # inside the timed region: simulates a slow (straggler) step
            time.sleep(step_delay_injector(step))
        state, metrics = step_fn(state, batch_data)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        times.append(dt)
        med = float(np.median(times[-20:]))
        if len(times) > 5 and dt > lcfg.straggler_factor * med:
            history["stragglers"].append((step, dt, med))
            log.warning("straggler: step %d took %.3fs (median %.3fs)",
                        step, dt, med)
        if step % lcfg.log_every == 0 or step == lcfg.steps - 1:
            history["loss"].append(float(metrics["loss"]))
            history["step"].append(step)
            log.info("step %d loss %.4f grad_norm %.3f", step,
                     float(metrics["loss"]), float(metrics["grad_norm"]))
        if mgr is not None and (step + 1) % lcfg.ckpt_every == 0:
            mgr.save(step + 1, {"state": state, "data": data.state_dict()})
    if mgr is not None:
        mgr.save(lcfg.steps, {"state": state, "data": data.state_dict()},
                 blocking=True)
    return history
