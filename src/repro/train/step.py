"""train_step factory: value_and_grad + clip + schedule + AdamW,
with microbatch gradient accumulation (scan) and optional int8
error-feedback compression of the cross-pod gradient reduction.

The returned function is pure: (state, batch) -> (state, metrics).
``state`` = {"params", "opt"(, "ef")}.  The launcher jits it with
in/out shardings from ``state_specs`` and donates the state.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import forward_train, init_params, param_specs
from repro.models.parallel import ParallelConfig
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, warmup_cosine)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    clip_norm: float = 1.0
    microbatch: int = 1            # grad-accumulation steps
    adamw: AdamWConfig = AdamWConfig()


def init_state(cfg: ArchConfig, key: jax.Array,
               tcfg: TrainConfig = TrainConfig()) -> Dict[str, Any]:
    params = init_params(cfg, key)
    return {"params": params, "opt": adamw_init(params)}


def state_specs(cfg: ArchConfig, par: ParallelConfig,
                tcfg: TrainConfig = TrainConfig()):
    """PartitionSpec pytree matching init_state (moments follow params)."""
    ps = param_specs(cfg, par)
    return {"params": ps, "opt": {"m": ps, "v": ps, "step": ()}}


def batch_specs(cfg: ArchConfig, par: ParallelConfig):
    b = par.batch()
    out = {"tokens": (b, None), "labels": (b, None)}
    if cfg.encoder_layers:
        out["frames"] = (b, None, None)
    if cfg.num_image_tokens:
        out["image_embeds"] = (b, None, None)
    return out


def make_train_step(cfg: ArchConfig, par: ParallelConfig,
                    tcfg: TrainConfig = TrainConfig()) -> Callable:
    def loss_fn(params, mb):
        loss, metrics = forward_train(params, mb, cfg, par)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if tcfg.microbatch > 1:
            nm = tcfg.microbatch
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((nm, x.shape[0] // nm) + x.shape[1:]),
                batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), metrics

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), metrics = jax.lax.scan(
                acc_body, (g0, jnp.float32(0)), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / nm, grads)
            loss = loss_sum / nm
            metrics = jax.tree_util.tree_map(lambda m: jnp.mean(m), metrics)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        lr = warmup_cosine(state["opt"]["step"], peak_lr=tcfg.peak_lr,
                           warmup_steps=tcfg.warmup_steps,
                           total_steps=tcfg.total_steps)
        new_params, new_opt = adamw_update(grads, state["opt"], params, lr,
                                           tcfg.adamw)
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out_metrics.update(metrics)
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step


def make_jitted_train_step(cfg: ArchConfig, par: ParallelConfig,
                           tcfg: TrainConfig = TrainConfig()):
    """jit with explicit in/out shardings + donated state."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    step = make_train_step(cfg, par, tcfg)
    if not par.active:
        return jax.jit(step, donate_argnums=0)
    mesh = par.mesh
    abstract = jax.eval_shape(
        lambda: init_state(cfg, jax.random.PRNGKey(0), tcfg))
    s_specs = jax.tree_util.tree_map(
        lambda a, s: NamedSharding(mesh, P(*s)), abstract,
        state_specs(cfg, par, tcfg))
    b_specs = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, P(*s)), batch_specs(cfg, par),
        is_leaf=lambda x: isinstance(x, tuple))
    return jax.jit(step, in_shardings=(s_specs, b_specs),
                   out_shardings=(s_specs, None), donate_argnums=0)
