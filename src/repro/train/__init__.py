from repro.train.loop import LoopConfig, train_loop
from repro.train.step import (TrainConfig, batch_specs, init_state,
                              make_jitted_train_step, make_train_step,
                              state_specs)

__all__ = ["LoopConfig", "train_loop", "TrainConfig", "batch_specs",
           "init_state", "make_jitted_train_step", "make_train_step",
           "state_specs"]
